"""Aggregate the dry-run JSONs into the §Roofline table (EXPERIMENTS.md).

Reads results/dryrun/*.json (written by scripts/run_dryruns.py) and emits a
markdown + CSV table: per (arch x shape x mesh) the three roofline terms,
the dominant term, MODEL_FLOPS/HLO ratio, and per-device memory.
"""
from __future__ import annotations

import glob
import json
import os


def load(results_dir="results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        t = r["roofline"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            devices=r.get("devices"),
            compute_s=t["compute_s"], memory_s=t["memory_s"],
            collective_s=t["collective_s"], bottleneck=t["bottleneck"],
            useful_ratio=t.get("useful_flops_ratio"),
            model_flops=t.get("model_flops"),
            flops_per_dev=r["cost_model"]["flops"],
            coll_bytes_per_dev=r["cost_model"]["collective_bytes"],
            mem_bytes_per_dev=r["cost_model"]["bytes"],
            xla_temp_bytes=r["full"]["memory"]["temp_bytes"],
            params_b=r.get("param_count", 0) / 1e9,
        ))
    return rows


def markdown(rows):
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "bottleneck | useful FLOPs ratio |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{(r['useful_ratio'] or 0):.3f} |")
    return "\n".join(lines)


def main():
    rows = load()
    if not rows:
        print("roofline,no_dryrun_results_found,run scripts/run_dryruns.py first")
        return
    print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,useful_ratio")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['bottleneck']},"
              f"{(r['useful_ratio'] or 0):.4f}")
    return rows


if __name__ == "__main__":
    main()
